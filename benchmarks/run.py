"""Benchmark harness: `PYTHONPATH=src python -m benchmarks.run`.

Runs the paper-claim benchmarks (B1-B8, B10, B11) plus the data-pipeline
throughput bench (B9), prints the results, and writes two artifacts:

  - benchmarks/results/koalja_bench.json — the full run (local detail)
  - BENCH_koalja.json (repo top level)   — a compact per-bench summary of
    the headline numbers, committed so the perf trajectory is tracked PR
    over PR.

Each bench executes in a **fresh interpreter** (hermetic mode, default):
allocator, GC, and import state left behind by one bench must not skew the
next one's timings — a heap warmed by B1-B13 makes B14's scalar-hash
baseline measure ~1.7x faster than any real cold process would, for
example. `KOALJA_BENCH_HERMETIC=0` restores the single-process run.

The roofline tables are produced separately by
`python -m repro.launch.dryrun --all` + `benchmarks.report` (they need the
512-device env, which must not leak into this process).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time


def bench_pipeline_throughput():
    from repro.configs import get_config
    from repro.data.pipeline import build_data_pipeline, next_batch

    cfg = get_config("stablelm-1.6b").reduced()
    mgr = build_data_pipeline(cfg, global_batch=8, seq_len=128)
    next_batch(mgr, cfg)  # warm
    t0 = time.perf_counter()
    n = 20
    for _ in range(n):
        next_batch(mgr, cfg)
    dt = time.perf_counter() - t0
    stats = mgr.stats()
    return {
        "batches_per_s": n / dt,
        "tokens_per_s": n * 8 * 128 / dt,
        "avs_carried": sum(l["carried"] for l in stats["links"].values()),
        "store_stats": stats["store"],
    }


# headline metric per bench for the committed trajectory file; a dotted
# path selects a nested value from the bench's result dict
_HEADLINES = {
    "B1_metadata_overhead": ["1024KB.metadata_frac"],
    "B2_cache_reuse": ["10_pushes.speedup"],
    "B3_transport_avoidance": ["link_payload_ratio"],
    "B4_notification_vs_polling": ["polls_until_arrival"],
    "B5_policy_throughput": [
        "merge.arrivals_per_s",
        "scheduler_vs_polling.scan_reduction_x",
        "scheduler_vs_polling.events_per_s",
    ],
    "B6_wireframe": ["cost_ratio"],
    "B7_concurrent_fanout": [
        "speedup",
        "sustainability_identical",
        "provenance_events_identical",
        "merge_fcfs_identical",
    ],
    "B8_repeated_push": ["execution_reduction_x", "bytes_not_moved"],
    "B9_pipeline_throughput": ["batches_per_s", "tokens_per_s"],
    "B11_journal_overhead": [
        "records_per_s",
        "bytes_per_record",
        "overhead_x",
        "replay_identical",
    ],
    "B13_journal_compaction": [
        "restart_speedup_x",
        "bytes_bounded",
        "fingerprint_identical",
        "records_compacted",
    ],
    "B10_edge_placement": [
        "bytes_reduction_x",
        "bytes_crosszone_all_to_cloud",
        "bytes_crosszone_data_gravity",
        "energy_j_data_gravity",
        "merge_order_identical",
        "provenance_events_identical",
        "zoned_ledger_identical",
    ],
    "B14_hotpath_throughput": [
        "hash.speedup_x",
        "hash.batched_mb_per_s",
        "journal.records_per_s",
        "journal.speedup_x",
        "coalesce.arrivals_per_s",
        "coalesce.speedup_x",
    ],
    "B15_multitenant": [
        "dedup_ratio_x",
        "push_p99_ms",
        "records_per_s",
        "bytes_saved",
    ],
    "B12_process_pool": [
        "speedup",
        "payload_bytes_over_pipe",
        "control_bytes_sent",
        "provenance_events_identical",
        "merge_fcfs_identical",
    ],
    "B16_diurnal_load": [
        "p99_push_s",
        "total_energy_j",
        "energy_margin_x",
        "latency_margin_x",
        "adaptive_resizes",
        "adaptive_beats_all_static",
    ],
}


def _dig(result, dotted):
    cur = result
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def summarize(results: dict) -> dict:
    """Compact {bench: {metric: value}} view for BENCH_koalja.json."""
    summary = {}
    for name, entry in results.items():
        if "error" in entry:
            summary[name] = {"error": entry["error"]}
            continue
        picks = {}
        for dotted in _HEADLINES.get(name, []):
            val = _dig(entry.get("result") or {}, dotted)
            if val is not None:
                picks[dotted] = val
        picks["bench_wall_s"] = round(entry.get("bench_wall_s", 0.0), 3)
        summary[name] = picks
    return summary


def _all_benches():
    from benchmarks.bench_koalja import ALL

    benches = dict(ALL)
    benches["B9_pipeline_throughput"] = bench_pipeline_throughput
    return benches


def _run_entry(fn) -> dict:
    t0 = time.perf_counter()
    try:
        return {"result": fn(), "bench_wall_s": time.perf_counter() - t0}
    except Exception as e:  # pragma: no cover
        return {"error": repr(e)}


def _run_hermetic(name: str, repo_root: str) -> dict:
    """One bench in a fresh interpreter (``--one`` child mode below)."""
    fd, out_path = tempfile.mkstemp(suffix=".json", prefix="koalja-bench-")
    os.close(fd)
    env = dict(os.environ)
    src = os.path.join(repo_root, "src")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (src, env.get("PYTHONPATH")) if p
    )
    try:
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "--one", name, "--out", out_path],
            cwd=repo_root,
            env=env,
        )
        if proc.returncode == 0 and os.path.getsize(out_path):
            with open(out_path) as f:
                return json.load(f)
        return {"error": f"hermetic run exited {proc.returncode}"}
    finally:
        os.unlink(out_path)


def main():
    if "--one" in sys.argv:  # child mode: run one bench, dump JSON, exit
        name = sys.argv[sys.argv.index("--one") + 1]
        out_path = sys.argv[sys.argv.index("--out") + 1]
        entry = _run_entry(_all_benches()[name])
        with open(out_path, "w") as f:
            json.dump(entry, f, default=str)
        return

    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    hermetic = os.environ.get("KOALJA_BENCH_HERMETIC", "1") != "0"
    results = {}
    for name, fn in _all_benches().items():
        if hermetic:
            results[name] = _run_hermetic(name, repo_root)
        else:
            results[name] = _run_entry(fn)
        status = "FAIL" if "error" in results[name] else "ok"
        print(f"[{status}] {name} ({results[name].get('bench_wall_s', 0):.2f}s)")
        for k, v in (results[name].get("result") or {}).items():
            print(f"    {k}: {v}")

    out_dir = os.path.join(os.path.dirname(__file__), "results")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "koalja_bench.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=2, default=str)
    print(f"\nwrote {path}")

    traj_path = os.path.join(repo_root, "BENCH_koalja.json")
    with open(traj_path, "w") as f:
        json.dump(summarize(results), f, indent=2, default=str, sort_keys=True)
        f.write("\n")
    print(f"wrote {traj_path}")

    failures = [n for n, r in results.items() if "error" in r]
    if failures:
        print("FAILED:", failures)
        sys.exit(1)


if __name__ == "__main__":
    main()
