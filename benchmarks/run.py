"""Benchmark harness: `PYTHONPATH=src python -m benchmarks.run`.

Runs the six paper-claim benchmarks (B1-B6) plus the data-pipeline
throughput bench, prints the results, and writes
benchmarks/results/koalja_bench.json. The roofline tables are produced
separately by `python -m repro.launch.dryrun --all` + `benchmarks.report`
(they need the 512-device env, which must not leak into this process).
"""

from __future__ import annotations

import json
import os
import sys
import time


def bench_pipeline_throughput():
    from repro.configs import get_config
    from repro.data.pipeline import build_data_pipeline, next_batch

    cfg = get_config("stablelm-1.6b").reduced()
    mgr = build_data_pipeline(cfg, global_batch=8, seq_len=128)
    next_batch(mgr, cfg)  # warm
    t0 = time.perf_counter()
    n = 20
    for _ in range(n):
        next_batch(mgr, cfg)
    dt = time.perf_counter() - t0
    stats = mgr.stats()
    return {
        "batches_per_s": n / dt,
        "tokens_per_s": n * 8 * 128 / dt,
        "avs_carried": sum(l["carried"] for l in stats["links"].values()),
        "store_stats": stats["store"],
    }


def main():
    from benchmarks.bench_koalja import ALL

    results = {}
    benches = dict(ALL)
    benches["B7_pipeline_throughput"] = bench_pipeline_throughput
    for name, fn in benches.items():
        t0 = time.perf_counter()
        try:
            results[name] = {"result": fn(), "bench_wall_s": time.perf_counter() - t0}
            status = "ok"
        except Exception as e:  # pragma: no cover
            results[name] = {"error": repr(e)}
            status = "FAIL"
        print(f"[{status}] {name} ({results[name].get('bench_wall_s', 0):.2f}s)")
        for k, v in (results[name].get("result") or {}).items():
            print(f"    {k}: {v}")

    out_dir = os.path.join(os.path.dirname(__file__), "results")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "koalja_bench.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=2, default=str)
    print(f"\nwrote {path}")
    failures = [n for n, r in results.items() if "error" in r]
    if failures:
        print("FAILED:", failures)
        sys.exit(1)


if __name__ == "__main__":
    main()
