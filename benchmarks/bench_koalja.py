"""Benchmarks quantifying the paper's claims (Koalja has no numeric tables;
each bench pins one qualitative claim to a number).

  B1  metadata overhead        §III.L  "cheap to keep traveller log metadata"
  B2  make-mode cache reuse    §III.F  "sparse updates allow enormous savings"
  B3  transport avoidance      §III.F  references vs payloads on links
  B4  notification vs polling  §III.F  Principle 1 (timescale separation)
  B5  snapshot policy cost     §III.I  all_new / swap / merge / window, plus
                                       the event scheduler's enqueued-vs-scan
                                       trigger-work scorecard
  B6  wireframing              §III.K  ghost batches expose routing at ~zero cost
  B7  concurrent fan-out       §III.J  waves of independent ready tasks run in
                                       parallel (ConcurrentExecutor) with
                                       provenance/merge-FCFS bit-identical to
                                       the serial backend
  B8  repeated push            §III.F  semantic memoization short-circuits the
                                       hot path: unchanged inputs re-pushed N
                                       times execute ~once and move ~no bytes
  B10 edge placement           §IV     data-gravity placement on an IoT fan-in
                                       moves >=5x fewer cross-zone bytes than
                                       naive all-to-cloud, with bit-identical
                                       provenance and merge order across
                                       Inline/Zoned executors
  B11 journal overhead         §III.L  durable provenance journal: records/s
                                       sustained, bytes on disk per event,
                                       and the push-throughput cost of the
                                       write-through vs in-memory stories
  B12 process pool             §IV     GIL-bound fan-out on forked worker
                                       processes (ProcessExecutor) runs >=2x
                                       faster than the serialized thread
                                       pool, with zero payload bytes over
                                       any pipe and provenance identical
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import SnapshotPolicy
from repro.topology import Topology
from repro.workspace import (
    AdaptiveExecutor,
    ConcurrentExecutor,
    InlineExecutor,
    Workspace,
    ZonedExecutor,
)


def _mlp_workspace(heavy_ms: float = 0.0, cache=None) -> Workspace:
    def stage_a(x):
        if heavy_ms:
            time.sleep(heavy_ms / 1e3)
        return {"y": x @ x.T}

    def stage_b(y):
        if heavy_ms:
            time.sleep(heavy_ms / 1e3)
        return {"z": y.sum(axis=0)}

    ws = Workspace("bench", cache=cache)
    a = ws.task(stage_a, name="a", inputs=["x"], outputs=["y"])
    b = ws.task(stage_b, name="b", inputs=["y"], outputs=["z"])
    a["y"] >> b["y"]
    return ws


def bench_metadata_overhead():
    """Bytes + wall time of full provenance vs payload size."""
    out = {}
    for size_kb in (64, 1024, 16384):
        payload = np.zeros((size_kb * 1024 // 4,), np.float32)
        mgr = _mlp_workspace()
        # reshape so the pipeline does real work
        n = int(np.sqrt(payload.size))
        t0 = time.perf_counter()
        mgr.push("a", x=payload[: n * n].reshape(n, n))
        dt = time.perf_counter() - t0
        meta_bytes = mgr.registry.overhead_bytes()
        out[f"{size_kb}KB"] = {
            "payload_bytes": int(payload.nbytes),
            "metadata_bytes": int(meta_bytes),
            "metadata_frac": meta_bytes / payload.nbytes,
            "wall_s": dt,
        }
    return out


def _push_identical(ws: Workspace, pushes: int, n: int = 64) -> float:
    """Shared repeated-push workload (B2/B8): push one seeded array
    ``pushes`` times — identical content every time — and return the wall."""
    x = np.random.RandomState(0).randn(n, n)
    t0 = time.perf_counter()
    for _ in range(pushes):
        ws.push("a", x=x)
    return time.perf_counter() - t0


def bench_cache_reuse():
    """Re-pushing unchanged inputs: executions avoided via content cache."""
    results = {}
    for pushes in (10,):
        mgr = _mlp_workspace(heavy_ms=5.0)
        cold_and_hits = _push_identical(mgr, pushes)
        stats = mgr.stats()
        execs = sum(t["executions"] for t in stats["tasks"].values())
        hits = sum(t["cache_hits"] for t in stats["tasks"].values())
        mgr2 = _mlp_workspace(heavy_ms=5.0, cache=False)
        no_cache = _push_identical(mgr2, pushes)
        results[f"{pushes}_pushes"] = {
            "executions_with_cache": execs,
            "cache_hits": hits,
            "wall_with_cache_s": cold_and_hits,
            "wall_without_cache_s": no_cache,
            "speedup": no_cache / max(cold_and_hits, 1e-9),
        }
    return results


def bench_transport_avoidance():
    """Links carry ~100-byte AVs while payloads stay in the store."""
    mgr = _mlp_workspace()
    x = np.random.RandomState(0).randn(512, 512)  # 2 MB
    mgr.push("a", x=x)
    total_payload = sum(
        v.nbytes for v in mgr.store._local.values() if hasattr(v, "nbytes")
    )
    import json

    av_bytes = 0
    for link in mgr.pipeline.links:
        pass
    # measure one AV record's size
    av = mgr.pipeline.tasks["a"].last_outputs["y"]
    av_bytes = len(json.dumps(av.to_record(), default=str))
    return {
        "payload_bytes_in_store": int(total_payload),
        "av_record_bytes": av_bytes,
        "link_payload_ratio": av_bytes / x.nbytes,
    }


def bench_notification_vs_polling():
    """Principle 1: for slow arrivals, notifications beat polling."""
    from repro.core import SmartLink, AnnotatedValue, ArtifactStore

    store = ArtifactStore()
    uri, h = store.put(1.0)

    # polling: consumer wakes every 0.1ms for 50ms until data arrives
    polls = 0
    link = SmartLink("l", "a", "b", "x")
    t_arrive = 0.02
    t0 = time.perf_counter()
    got = None
    while got is None:
        if time.perf_counter() - t0 >= t_arrive and link.peek_count() == 0:
            link.offer(AnnotatedValue.produce(h, uri, "a", "v"))
        got = link.poll()
        polls += 1

    # notification: zero polls — callback fires on offer
    link2 = SmartLink("l2", "a", "b", "x")
    notified = []
    link2.subscribe(lambda l, av: notified.append(av))
    link2.offer(AnnotatedValue.produce(h, uri, "a", "v"))
    return {
        "polls_until_arrival": polls,
        "notification_callbacks": len(notified),
        "poll_waste_ratio": polls / 1.0,
    }


def bench_policy_throughput():
    out = {}
    N = 20000
    for mode, inputs in (
        ("all_new", ["a", "b"]),
        ("swap_new_for_old", ["a", "b"]),
        ("merge", ["a", "b"]),
    ):
        p = SnapshotPolicy(inputs, mode=mode)
        t0 = time.perf_counter()
        snaps = 0
        for i in range(N):
            p.arrive("a", i)
            p.arrive("b", i)
            while p.ready():
                p.snapshot()
                snaps += 1
        dt = time.perf_counter() - t0
        out[mode] = {"arrivals_per_s": 2 * N / dt, "snapshots": snaps}
    p = SnapshotPolicy(["a[16/4]"], mode="all_new")
    t0 = time.perf_counter()
    snaps = 0
    for i in range(N):
        p.arrive("a", i)
        while p.ready():
            p.snapshot()
            snaps += 1
    dt = time.perf_counter() - t0
    out["window_16_4"] = {"arrivals_per_s": N / dt, "snapshots": snaps}
    out["scheduler_vs_polling"] = _bench_scheduler_vs_polling()
    return out


def _bench_scheduler_vs_polling(pushes: int = 200, cold_tasks: int = 13):
    """Trigger-work scorecard on a sparse circuit: a hot 3-stage chain inside
    a larger breadboard. The event scheduler enqueues only the notified
    tasks; the seed's polling engine would have rescanned every task every
    round. (The ISSUE 3 acceptance criterion: enqueued << scan-equivalent,
    results unchanged.)"""
    ws = Workspace("sparse", cache=False)
    a = ws.task(lambda x: {"y": x + 1}, name="a", inputs=["x"], outputs=["y"])
    b = ws.task(lambda x: {"y": x + 1}, name="b", inputs=["x"], outputs=["y"])
    c = ws.task(lambda x: {"y": x + 1}, name="c", inputs=["x"], outputs=["y"])
    a["y"] >> b["x"]
    b["y"] >> c["x"]
    for i in range(cold_tasks):
        ws.task(lambda q: {"r": q}, name=f"cold{i}", inputs=["q"], outputs=["r"])
    t0 = time.perf_counter()
    for i in range(pushes):
        ws.push("a", x=i)
    dt = time.perf_counter() - t0
    final = ws.value_of(ws.pipeline.tasks["c"].last_outputs["y"])
    sched = ws.stats()["scheduler"]
    return {
        "tasks_in_circuit": 3 + cold_tasks,
        "pushes": pushes,
        "events_per_s": pushes / dt,
        "tasks_enqueued": sched["tasks_enqueued"],
        "polling_scan_equivalent": sched["polling_scan_equivalent"],
        "scan_reduction_x": sched["scan_reduction_x"],
        "result_check": final == pushes - 1 + 3,
    }


def _fanout_workspace(width: int, heavy_ms: float, executor) -> Workspace:
    """src fans one push out to `width` independent workers (distinct input
    content per worker, so nothing memo-collides), merge-FCFS into a sink."""
    ws = Workspace("fanout", executor=executor)
    outs = [f"o{i}" for i in range(width)]

    def src(x):
        return {f"o{i}": x + i for i in range(width)}

    s = ws.task(src, name="src", inputs=["x"], outputs=outs)

    def work(v):
        time.sleep(heavy_ms / 1e3)
        return {"w": v * 2}

    sink = ws.task(
        lambda merged: {"total": list(merged)},
        name="sink",
        inputs=[f"i{i}" for i in range(width)],
        outputs=["total"],
        mode="merge",
    )
    for i in range(width):
        w = ws.task(work, name=f"w{i}", inputs=["v"], outputs=["w"])
        s[f"o{i}"] >> w["v"]
        w["w"] >> sink[f"i{i}"]
    return ws


def bench_concurrent_fanout(width: int = 8, heavy_ms: float = 5.0, pushes: int = 4):
    """ISSUE 3 acceptance: an 8-wide fan-out of 5 ms tasks must run >=2x
    faster under ConcurrentExecutor(max_workers=8) than InlineExecutor,
    while sustainability stats, provenance event counts, and the merge-FCFS
    order stay identical (deferred serial emission)."""
    runs = {}
    for label, executor in (
        ("inline", InlineExecutor()),
        ("concurrent", ConcurrentExecutor(max_workers=width)),
    ):
        ws = _fanout_workspace(width, heavy_ms, executor)
        t0 = time.perf_counter()
        for i in range(pushes):
            ws.push("src", x=i * 1000)  # distinct content every push
        wall = time.perf_counter() - t0
        stats = ws.stats()
        events = sorted(
            (t, e["event"]) for t in ws.tasks() for e in ws.visitor_log(t)
        )
        runs[label] = {
            "wall_s": wall,
            "sustainability": stats["sustainability"],
            "events": events,
            "merge_order": ws.value_of(
                ws.pipeline.tasks["sink"].last_outputs["total"]
            ),
            "waves": stats["scheduler"]["waves"],
        }
    inline, conc = runs["inline"], runs["concurrent"]
    return {
        "width": width,
        "heavy_ms": heavy_ms,
        "pushes": pushes,
        "wall_inline_s": inline["wall_s"],
        "wall_concurrent_s": conc["wall_s"],
        "speedup": inline["wall_s"] / max(conc["wall_s"], 1e-9),
        "sustainability_identical": inline["sustainability"] == conc["sustainability"],
        "provenance_events_identical": inline["events"] == conc["events"],
        "merge_fcfs_identical": inline["merge_order"] == conc["merge_order"],
    }


def bench_wireframe():
    """Ghost batches trace routing at a tiny fraction of real execution."""
    import jax
    import jax.numpy as jnp

    def heavy(x):
        return {"y": jnp.tanh(x @ x) @ x}

    mgr = _rebuild_wf(heavy)
    t0 = time.perf_counter()
    report = mgr.ghost({("h", "x"): jax.ShapeDtypeStruct((1024, 1024), jnp.float32)})
    ghost_s = time.perf_counter() - t0

    mgr2 = _rebuild_wf(heavy)
    x = jnp.asarray(np.random.RandomState(0).randn(1024, 1024), jnp.float32)
    t0 = time.perf_counter()
    mgr2.push("h", x=x)
    real_s = time.perf_counter() - t0
    return {
        "ghost_s": ghost_s,
        "real_s": real_s,
        "cost_ratio": ghost_s / max(real_s, 1e-9),
        "routes_traced": len(report["routes"]),
    }


def _rebuild_wf(heavy) -> Workspace:
    ws = Workspace("wf")
    h = ws.task(heavy, name="h", inputs=["x"], outputs=["y"])
    sm = ws.task(lambda y: {"z": y.sum()}, name="s", inputs=["y"], outputs=["z"])
    h["y"] >> sm["y"]
    return ws


def bench_repeated_push(pushes: int = 10):
    """The sustainability workload (§III.F): re-push byte-identical inputs.

    Only the first push executes user code; every later push short-circuits
    on the memo key (software version, input hashes, policy mode), emits
    ``cache_hit`` visitor events, and moves no payload bytes. Reports the
    execution reduction vs a cache-disabled circuit and the bytes the
    circuit never moved.
    """
    ws = _mlp_workspace(heavy_ms=2.0)
    wall = _push_identical(ws, pushes, n=128)
    stats = ws.stats()
    execs = sum(t["executions"] for t in stats["tasks"].values())
    n_tasks = len(stats["tasks"])
    cache_hit_events = sum(
        1
        for task in stats["tasks"]
        for e in ws.visitor_log(task)
        if e["event"] == "cache_hit"
    )
    return {
        "pushes": pushes,
        "executions": execs,
        "executions_without_cache": pushes * n_tasks,
        "execution_reduction_x": (pushes * n_tasks) / max(execs, 1),
        "executions_avoided": stats["sustainability"]["executions_avoided"],
        "cache_hit_events": cache_hit_events,
        "bytes_not_moved": stats["sustainability"]["bytes_not_moved"],
        "wall_s": wall,
    }


def _edge_fanin_workspace(placement, executor=None, zones=3, sensors=8):
    """IoT-style fan-in (the paper's §IV edge story): `zones` edge sites,
    each with `sensors` edge-pinned sources feeding one floating per-zone
    aggregator; a cloud-pinned reducer merge-FCFSes the aggregates. Under
    `pin` placement the floating aggregators land in the default (cloud)
    zone and every raw reading crosses the edge->cloud link; under
    `data_gravity` each aggregator is co-located with its zone's bytes and
    only the (sensors-times-smaller) aggregates cross."""
    topo = Topology("iot")
    topo.zone("cloud", tier="cloud")
    zone_names = [f"edge-{i}" for i in range(zones)]
    for z in zone_names:
        topo.zone(z, tier="edge")
        topo.link("cloud", z, bandwidth_mbps=50, latency_ms=20, energy_j_per_mb=0.05)
    ws = Workspace(
        "edge-fanin", topology=topo, placement=placement,
        executor=executor, cache=False,
    )
    for z in zone_names:
        for i in range(sensors):
            ws.source(
                lambda: {"reading": np.zeros(4, np.float32)},
                name=f"s_{z}_{i}", outputs=["reading"],
            ).place(z)
        agg = ws.task(
            lambda **kw: {"agg": sum(kw.values())},
            name=f"agg_{z}", inputs=[f"r{i}" for i in range(sensors)],
            outputs=["agg"],
        )
        for i in range(sensors):
            ws[f"s_{z}_{i}"]["reading"] >> agg[f"r{i}"]
    red = ws.task(
        lambda merged: {"total": [float(np.sum(m)) for m in merged]},
        name="reduce", inputs=[f"a_{z}" for z in zone_names],
        outputs=["total"], mode="merge",
    ).place("cloud")
    for z in zone_names:
        ws[f"agg_{z}"]["agg"] >> red[f"a_{z}"]
    return ws, zone_names


def _drive_edge_fanin(ws, zone_names, rounds, n, sensors):
    rng = np.random.RandomState(0)
    for _ in range(rounds):
        for z in zone_names:
            for i in range(sensors):
                ws.push(f"s_{z}_{i}", reading=rng.randn(n).astype(np.float32))
    stats = ws.stats()
    return {
        "ledger": stats["topology"]["ledger"],
        "merge_order": ws.value_of(ws.pipeline.tasks["reduce"].last_outputs["total"]),
        "events": sorted(
            (t, e["event"]) for t in ws.tasks() for e in ws.visitor_log(t)
        ),
        "zones": {
            z: v["executions"] for z, v in stats["topology"]["zones"].items()
        },
    }


def bench_edge_placement(zones=3, sensors=8, rounds=3, n=256):
    """ISSUE 4 acceptance: on the IoT fan-in, data-gravity placement must
    move >=5x fewer cross-zone bytes than naive all-to-cloud (`pin` with
    floating aggregators), with identical results, provenance events, and
    merge-FCFS order — including under ZonedExecutor(inner=Concurrent)."""
    runs = {}
    for label, placement, executor in (
        ("all_to_cloud", "pin", None),
        ("data_gravity", "data_gravity", None),
        ("data_gravity_zoned", "data_gravity",
         ZonedExecutor(inner=ConcurrentExecutor(max_workers=4))),
    ):
        ws, zone_names = _edge_fanin_workspace(placement, executor, zones, sensors)
        runs[label] = _drive_edge_fanin(ws, zone_names, rounds, n, sensors)
    pin_led = runs["all_to_cloud"]["ledger"]
    grav_led = runs["data_gravity"]["ledger"]
    return {
        "zones": zones,
        "sensors_per_zone": sensors,
        "rounds": rounds,
        "reading_bytes": n * 4,
        "bytes_crosszone_all_to_cloud": pin_led["bytes_moved_crosszone"],
        "bytes_crosszone_data_gravity": grav_led["bytes_moved_crosszone"],
        "bytes_reduction_x": pin_led["bytes_moved_crosszone"]
        / max(grav_led["bytes_moved_crosszone"], 1),
        "energy_j_all_to_cloud": pin_led["transfer_energy_j"],
        "energy_j_data_gravity": grav_led["transfer_energy_j"],
        "merge_order_identical": (
            runs["all_to_cloud"]["merge_order"]
            == runs["data_gravity"]["merge_order"]
            == runs["data_gravity_zoned"]["merge_order"]
        ),
        "provenance_events_identical": (
            runs["all_to_cloud"]["events"]
            == runs["data_gravity"]["events"]
            == runs["data_gravity_zoned"]["events"]
        ),
        "zoned_ledger_identical": (
            runs["data_gravity"]["ledger"] == runs["data_gravity_zoned"]["ledger"]
        ),
        "edge_executions_gravity": sum(
            v for z, v in runs["data_gravity"]["zones"].items() if z != "cloud"
        ),
    }


def bench_journal_overhead(pushes: int = 200):
    """ISSUE 5: price the durable journal. The same 2-stage circuit is
    pushed ``pushes`` times with fresh content (every firing executes) with
    the journal off and on; the delta is the cost of durability, reported
    as sustained journal records/sec and bytes on disk per record. A replay
    at the end proves the log actually rehydrates (records == replayed)."""
    import os
    import tempfile

    from repro.provenance import replay_journal

    def build(journal_path):
        ws = Workspace("bench-journal", journal_path=journal_path, topology=False)
        a = ws.task(lambda x: {"y": x * 2.0}, name="a", inputs=["x"], outputs=["y"])
        b = ws.task(lambda y: {"z": float(y.sum())}, name="b", inputs=["y"], outputs=["z"])
        a["y"] >> b["y"]
        return ws, a

    def drive(ws, a):
        t0 = time.perf_counter()
        for i in range(pushes):
            ws.push(a, x=np.full(64, float(i), np.float32))
        return time.perf_counter() - t0

    # best-of-3 per leg: the rate folds in full engine wall time, and a
    # single pass is hostage to scheduler/fsync jitter on a loaded host
    wall_memory = min(drive(*build(False)) for _ in range(3))

    wall_journal = float("inf")
    for _ in range(3):
        path = os.path.join(tempfile.mkdtemp(prefix="koalja-bench-"), "bench.jsonl")
        ws_j, a_j = build(path)
        wall_journal = min(wall_journal, drive(ws_j, a_j))
        ws_j.journal.close()
    js = ws_j.journal.stats()
    replayed = replay_journal(path)

    return {
        "pushes": pushes,
        "records_written": js["records_written"],
        "bytes_on_disk": js["bytes_on_disk"],
        "bytes_per_record": js["bytes_on_disk"] / max(js["records_written"], 1),
        "flushes": js["flushes"],
        "records_per_s": js["records_written"] / max(wall_journal, 1e-9),
        "wall_memory_s": wall_memory,
        "wall_journal_s": wall_journal,
        "overhead_x": wall_journal / max(wall_memory, 1e-9),
        "replay_identical": (
            replayed.registry.visitor_log("b") == ws_j.visitor_log("b")
            and replayed.registry.design_map() == ws_j.design_map()
        ),
    }


def bench_process_pool(width: int = 8, gil_ms: float = 30.0, pushes: int = 3):
    """ISSUE 6 acceptance: an 8-wide fan-out of GIL-bound tasks must run
    >=2x faster on the forked ProcessExecutor pool than on the
    ConcurrentExecutor thread pool, with zero payload bytes crossing any
    pipe (the reference-handover protocol: payloads ride the shared object
    tier) and the per-task provenance story identical to the thread-pool
    run.

    The per-task work is a C call that *holds* the GIL for ``gil_ms``
    (``ctypes.PyDLL`` — like a plugin extension that never releases it):
    threads serialize on it, forked workers don't. Unlike a pure-Python
    busy loop, this isolates the GIL-escape effect from the host's core
    count, so the >=2x shows deterministically even on a single-core CI
    container (a busy loop needs >= ``width`` cores to show the same
    wall-clock gap)."""
    import ctypes

    from repro.runtime import ProcessExecutor

    libc = ctypes.PyDLL(None)  # PyDLL: calls do NOT release the GIL

    def _build(executor):
        ws = Workspace("bench-pool", executor=executor, cache=False, topology=False)
        src = ws.task(lambda x: {"out": x}, name="src", inputs=["x"], outputs=["out"])
        sink = ws.task(
            lambda **kw: {"total": [float(kw[k]) for k in sorted(kw)]},
            name="sink", inputs=[f"v{i}" for i in range(width)], outputs=["total"],
        )
        for i in range(width):
            def burn(y, i=i, us=int(gil_ms * 1000)):
                libc.usleep(us)  # blocks holding the GIL
                return {"v": float(np.sum(y)) + i}
            t = ws.task(burn, name=f"burn{i}", inputs=["y"], outputs=["v"])
            src["out"] >> t["y"]
            t["v"] >> sink[f"v{i}"]
        return ws

    runs = {}
    for label, executor in (
        ("concurrent", ConcurrentExecutor(max_workers=width)),
        ("process", ProcessExecutor(max_workers=width)),
    ):
        ws = _build(executor)
        payload = np.full(256, 1.0, np.float32)
        ws.push("src", x=payload * 0.0)  # warm: forks the pool off-clock
        t0 = time.perf_counter()
        for i in range(pushes):
            ws.push("src", x=payload * (i + 1))
        wall = time.perf_counter() - t0
        events = sorted(
            (t, e["event"]) for t in ws.tasks() for e in ws.visitor_log(t)
        )
        runs[label] = {
            "wall_s": wall,
            "events": events,
            "merge_order": ws.value_of(
                ws.pipeline.tasks["sink"].last_outputs["total"]
            ),
            "stats": executor.stats(),
        }
        if hasattr(executor, "shutdown"):
            executor.shutdown()
    conc, proc = runs["concurrent"], runs["process"]
    pstats = proc["stats"]
    payload_bytes_shared = (pushes + 1) * width * 256 * 4  # what moved via store
    return {
        "width": width,
        "gil_ms": gil_ms,
        "pushes": pushes,
        "wall_concurrent_s": conc["wall_s"],
        "wall_process_s": proc["wall_s"],
        "speedup": conc["wall_s"] / max(proc["wall_s"], 1e-9),
        "tasks_remote": pstats["tasks_remote"],
        "control_bytes_sent": pstats["control_bytes_sent"],
        "control_bytes_received": pstats["control_bytes_received"],
        "payload_bytes_over_pipe": pstats["payload_bytes_over_pipe"],
        "payload_bytes_shared_tier": payload_bytes_shared,
        "provenance_events_identical": conc["events"] == proc["events"],
        "merge_fcfs_identical": conc["merge_order"] == proc["merge_order"],
    }


def bench_journal_compaction(rounds: int = 8, pushes_per_round: int = 40):
    """ISSUE 7 acceptance: journal at production scale. A long-running
    streaming workload (fresh content every push, so every firing executes
    and journals) rotates its journal and compacts each round, retiring
    AVs whose payloads the store evicted. Three claims are priced:

    - restart cost: rehydrating via checkpoint + tail must be >= 10x
      faster than replaying the full record history (the uncompacted
      oracle over the archived segments),
    - boundedness: on-disk journal bytes must not grow monotonically
      across rounds (steady state, not O(lifetime)),
    - fidelity: the checkpointed replay's registry fingerprint must be
      byte-identical to the uncompacted oracle's.
    """
    import json
    import os
    import tempfile

    from repro.provenance import discover_chain, replay_files, replay_journal

    root = tempfile.mkdtemp(prefix="koalja-bench-")
    base = os.path.join(root, "compact.jsonl")
    archive = os.path.join(root, "archive")
    ws = Workspace(
        "bench-compaction", journal_path=base, topology=False, cache=False,
        journal_rotate_records=256,
    )
    a = ws.task(lambda x: {"y": x * 2.0}, name="a", inputs=["x"], outputs=["y"])
    b = ws.task(lambda y: {"z": float(y.sum())}, name="b", inputs=["y"], outputs=["z"])
    a["y"] >> b["y"]

    bytes_per_round = []
    keep = 4  # live working set: everything older is evicted + retired
    for r in range(rounds):
        for i in range(pushes_per_round):
            ws.push(a, x=np.full(64, float(r * pushes_per_round + i), np.float32))
        for uid in ws.registry.all_avs()[:-keep]:
            av = ws.registry.get_av(uid)
            if not av.uri.startswith("ghost://"):
                ws.store.evict_local(av.uri)
        ws.compact_journal(retire_evicted=True, archive_dir=archive)
        bytes_per_round.append(ws.journal.stats()["bytes_on_disk"])
    ws.journal.flush()
    js = ws.journal.stats()

    oracle_files = sorted(
        os.path.join(archive, n) for n in os.listdir(archive)
    ) + discover_chain(base)["segments"] + [base]

    t0 = time.perf_counter()
    oracle = replay_files(oracle_files)
    wall_full = time.perf_counter() - t0
    wall_ckpt = min(
        _timed(lambda: replay_journal(base))[1] for _ in range(3)
    )
    restored = replay_journal(base)

    def fingerprint(registry):
        state = registry.snapshot_state()
        state.pop("next_seq", None)
        state["avs"] = sorted(state["avs"], key=lambda x: x["av"]["uid"])
        return json.dumps(state, sort_keys=True, default=repr)

    steady = bytes_per_round[len(bytes_per_round) // 2:]
    return {
        "rounds": rounds,
        "pushes_per_round": pushes_per_round,
        "records_full_history": oracle.records,
        "records_checkpoint_replay": restored.records,
        "records_compacted": js["records_compacted"],
        "bytes_reclaimed": js["bytes_reclaimed"],
        "bytes_on_disk_per_round": bytes_per_round,
        "bytes_bounded": max(steady) <= 2 * bytes_per_round[0],
        "wall_full_replay_s": wall_full,
        "wall_checkpoint_replay_s": wall_ckpt,
        "restart_speedup_x": wall_full / max(wall_ckpt, 1e-9),
        "fingerprint_identical": fingerprint(restored.registry)
        == fingerprint(oracle.registry),
    }


def bench_hotpath_throughput(wave_width: int = 64, journal_records: int = 4000):
    """ISSUE 8: the vectorized data plane, leg by leg.

    - ``hash``: a 64-wide wave of >4 MiB arrays digested by
      ``content_hash_batch`` (blockwise tree digest on the large tier) vs
      the per-AV scalar baseline — full-coverage sha256 per payload, the
      cost the old sampled-stripe hash was dodging by under-reading.
    - ``journal``: one ``append_batch`` (fused encode, one lock, one write
      decision) vs per-record ``append`` for the same record stream.
    - ``coalesce``: arrivals/s through a 2-stage chain with
      ``TaskHandle.coalesce`` on vs off (same outputs, fewer waves).
    """
    import hashlib
    import os
    import tempfile

    from repro.core.hashing import content_hash_batch
    from repro.provenance import Journal

    # -- hash leg ----------------------------------------------------------
    rng = np.random.RandomState(0)
    nbytes = (1 << 22) + (1 << 19)  # 4.5 MiB: safely in the tree tier
    wave = [
        rng.randint(0, 255, size=nbytes, dtype=np.uint8) for _ in range(wave_width)
    ]

    def scalar_full_sha():  # the per-AV baseline: full-coverage sha256
        return [
            hashlib.sha256(
                a.tobytes() + str(a.shape).encode() + str(a.dtype).encode()
            ).hexdigest()[:16]
            for a in wave
        ]

    scalar_full_sha()  # warm
    content_hash_batch(wave)
    # best-of-3 per leg: a single pass over a ~288 MiB working set is noisy
    # under suite load, and the minimum is the honest cost of either path
    wall_scalar = min(_timed(scalar_full_sha)[1] for _ in range(3))
    wall_batch = min(_timed(lambda: content_hash_batch(wave))[1] for _ in range(3))
    total_mb = wave_width * nbytes / 2**20

    # -- journal leg -------------------------------------------------------
    # Primary numbers use the *durable* configuration (flush_every_n=1, the
    # zone-runner setting: a record is fsync-durable before the reply that
    # references it leaves the process). There per-record append pays one
    # fsync per record while append_batch makes one write/fsync decision per
    # batch — the fusion the batch API exists for. The buffered default
    # (flush_every_n=64) is reported alongside as the encode-dominated view.
    records = [
        (
            "visit",
            {
                "task": "bench", "av_uid": f"av-{i:06d}", "event": "executed",
                "timestamp": 1723100000.0 + i, "software_version": "v1",
                "note": f"wall={i % 17}.000e-03s", "seq": i,
            },
        )
        for i in range(journal_records)
    ]
    tmp = tempfile.mkdtemp(prefix="koalja-bench-hotpath-")

    def journal_pair(tag, flush_every_n, n_records):
        recs = records[:n_records]
        j1 = Journal(
            os.path.join(tmp, f"scalar-{tag}.jsonl"), flush_every_n=flush_every_n
        )
        def per_record():
            for kind, data in recs:
                j1.append(kind, data)
        _, wall_scalar = _timed(per_record)
        j1.close()
        j2 = Journal(
            os.path.join(tmp, f"batch-{tag}.jsonl"), flush_every_n=flush_every_n
        )
        _, wall_batch = _timed(lambda: j2.append_batch(recs))
        j2.close()
        return {
            "records": n_records,
            "scalar_records_per_s": n_records / max(wall_scalar, 1e-9),
            "records_per_s": n_records / max(wall_batch, 1e-9),
            "speedup_x": wall_scalar / max(wall_batch, 1e-9),
        }

    durable = journal_pair("durable", 1, min(journal_records, 1000))
    buffered = journal_pair("buffered", None, journal_records)

    # -- coalesce leg ------------------------------------------------------
    def drive(coalesce):
        ws = Workspace("bench-coalesce", topology=False, cache=False)
        t = ws.task(
            lambda x: {"y": x + 1.0}, name="inc", inputs=["x"], outputs=["y"]
        )
        d = ws.task(
            lambda y: {"z": y * 2.0}, name="dbl", inputs=["y"], outputs=["z"]
        )
        t["y"] >> d["y"]
        if coalesce:
            t.coalesce(32)
            d.coalesce(32)
        n = 400
        arrivals = [np.full(8, float(i), np.float32) for i in range(n)]
        t0 = time.perf_counter()
        for a in arrivals:
            ws.inject(t, "x", a)
        ws.manager.propagate()
        wall = time.perf_counter() - t0
        waves = ws.stats()["scheduler"]["waves"]
        return n / wall, waves

    aps_off, waves_off = drive(False)
    aps_on, waves_on = drive(True)

    return {
        "hash": {
            "wave_width": wave_width,
            "mb_hashed": total_mb,
            "scalar_mb_per_s": total_mb / max(wall_scalar, 1e-9),
            "batched_mb_per_s": total_mb / max(wall_batch, 1e-9),
            "speedup_x": wall_scalar / max(wall_batch, 1e-9),
        },
        "journal": {**durable, "buffered": buffered},
        "coalesce": {
            "arrivals_per_s": aps_on,
            "arrivals_per_s_uncoalesced": aps_off,
            "speedup_x": aps_on / max(aps_off, 1e-9),
            "waves": waves_on,
            "waves_uncoalesced": waves_off,
        },
    }


def _mt_src(x):
    return {"out": x * 2.0}


def _mt_left(v):
    return {"y": v + 1.0}


def _mt_right(v):
    return {"y": v - 1.0}


def _mt_join(a, b):
    return {"out": float(a.sum() + b.sum())}


def bench_multitenant(tenants: int = 64, working_set: int = 8):
    """ISSUE 9: multi-tenant hub with cross-tenant memo dedup.

    ``tenants`` workspaces share one hub — one content-addressed store, one
    hub memo index, one journal seq space — and each pushes the same
    ``working_set`` of artifacts through a 4-task fan-out circuit (rotated
    so every tenant starts at a different artifact). The first tenant to
    push a given artifact computes; every later identical push replays the
    bytes from the shared store with a hub-level lineage credit. Reports
    the dedup ratio (logical firings / firings actually executed),
    per-tenant push latency (p50/p99 across all tenants' pushes), and the
    sustained journal record rate across the hub chain (control plane +
    every tenant segment, journaling on).
    """
    import os
    import tempfile

    from repro.tenancy import WorkspaceHub

    tmp = tempfile.mkdtemp(prefix="koalja-bench-mt-")
    hub = WorkspaceHub(
        "bench-hub",
        journal_path=os.path.join(tmp, "hub.jsonl"),
        executor_factory=InlineExecutor,
        workspace_defaults={"topology": False},
    )
    sessions = []
    for i in range(tenants):
        s = hub.create(f"tenant-{i:03d}", owner="bench")
        src = s.task(_mt_src, name="src", inputs=["x"], outputs=["out"])
        left = s.task(_mt_left, name="left", inputs=["v"], outputs=["y"])
        right = s.task(_mt_right, name="right", inputs=["v"], outputs=["y"])
        join = s.task(_mt_join, name="join", inputs=["a", "b"], outputs=["out"])
        s.wire(src["out"], left["v"])
        s.wire(src["out"], right["v"])
        s.wire(left["y"], join["a"])
        s.wire(right["y"], join["b"])
        sessions.append(s)
    payloads = [np.full(256, float(p), np.float32) for p in range(working_set)]
    latencies = []
    t0 = time.perf_counter()
    for i, s in enumerate(sessions):
        for k in range(working_set):
            p = payloads[(i + k) % working_set]
            t1 = time.perf_counter()
            s.push("src", x=p)
            latencies.append(time.perf_counter() - t1)
    hub.flush()
    wall = time.perf_counter() - t0
    memo = hub.memo.stats()
    logical = tenants * working_set * 4  # 4 firings per push
    executed = logical - memo["executions_avoided"]
    records = hub.journal.stats()["records_written"] + sum(
        s.ws.journal.stats()["records_written"] for s in sessions
    )
    latencies.sort()
    hub.shutdown()
    return {
        "tenants": tenants,
        "working_set": working_set,
        "pushes": tenants * working_set,
        "logical_firings": logical,
        "executions_avoided": memo["executions_avoided"],
        "bytes_saved": memo["bytes_saved"],
        "dedup_ratio_x": logical / max(executed, 1),
        "push_p50_ms": latencies[len(latencies) // 2] * 1e3,
        "push_p99_ms": latencies[int(len(latencies) * 0.99)] * 1e3,
        "records_written": records,
        "records_per_s": records / max(wall, 1e-9),
    }


def _diurnal_topology() -> Topology:
    """Device fleet with a nearby edge rack and a distant cloud: the
    device->edge hop is a local radio link (fast, cheap), device->cloud a
    metered WAN uplink (slow, expensive), and compute joules per MB rise
    toward the battery-powered leaf (cloud 0.02 < edge 0.05 < device 0.12,
    the tier defaults)."""
    t = Topology("iot-diurnal")
    t.zone("cloud", tier="cloud")
    t.zone("edge", tier="edge")
    t.zone("device", tier="device")
    t.link("device", "edge", latency_ms=1, bandwidth_mbps=1000,
           energy_j_per_mb=0.01)
    t.link("edge", "cloud", latency_ms=20, bandwidth_mbps=100,
           energy_j_per_mb=0.05)
    t.link("device", "cloud", latency_ms=50, bandwidth_mbps=10,
           energy_j_per_mb=0.5)
    return t


def _diurnal_ws(placement, executor, widths, work_ms):
    """One fan per load level: src_w (pinned device) -> w analyzers
    (floating -- the placement policy decides) -> red_w (pinned cloud).
    Pushing src_w fires one wave of width w, so the diurnal schedule below
    drives exactly the wave widths it names."""

    def _analyze(y, j=0):
        if work_ms:
            time.sleep(work_ms / 1e3)
        return {"s": float(np.sum(y * y)) + j}

    # cache=False: a serial pool memo-dedupes identical analyzers inside a
    # wave while a parallel pool races past the insert, so leaving the memo
    # on would make the *compute* account depend on pool size; this bench
    # prices execution, not memoization (B2/B8 own that story)
    ws = Workspace("bench-diurnal", topology=_diurnal_topology(),
                   placement=placement, executor=executor, cache=False)
    for w in widths:
        src = ws.task(lambda x: {"out": x}, name=f"src{w}",
                      inputs=["x"], outputs=["out"]).place("device")
        red = ws.task(lambda **kw: {"total": sum(kw.values())},
                      name=f"red{w}", inputs=[f"v{i}" for i in range(w)],
                      outputs=["total"]).place("cloud")
        for i in range(w):
            an = ws.task(lambda y, i=i: _analyze(y, i), name=f"an{w}_{i}",
                         inputs=["y"], outputs=["s"])
            src["out"] >> an["y"]
            an["s"] >> red[f"v{i}"]
    return ws


def _drive_diurnal(ws, schedule, n, rng_seed=7):
    """Push one reading per round; the round's latency is the push wall time
    plus the *modeled* WAN time of the bytes the round moved cross-zone
    (per-pair ledger deltas priced with the topology's latency/bandwidth --
    the same at-read-time pricing the energy account uses, since the
    in-process engine does not physically cross a WAN)."""
    rng = np.random.RandomState(rng_seed)
    topo = ws.manager.topology
    pair_seen: dict = {}
    lat = []
    for w in schedule:
        x = rng.randn(n).astype(np.float32)
        t0 = time.perf_counter()
        ws.push(f"src{w}", x=x)
        dt = time.perf_counter() - t0
        by_pair = ws.manager.ledger.stats()["by_pair"]
        for pair, total in by_pair.items():
            moved = total - pair_seen.get(pair, 0)
            if moved > 0:
                src, dst = pair.split("->")
                dt += topo.transfer_time_s(src, dst, moved)
            pair_seen[pair] = total
        lat.append(dt)
    led = ws.manager.ledger.stats()
    lat.sort()
    p99 = lat[min(len(lat) - 1, max(0, int(len(lat) * 0.99 + 0.999999) - 1))]
    ex = ws.executor
    out = {
        "p99_push_s": p99,
        "p50_push_s": lat[len(lat) // 2],
        "total_energy_j": led["total_energy_j"],
        "transfer_energy_j": led["transfer_energy_j"],
        "compute_energy_j": led["compute_energy_j"],
        "bytes_crosszone": led["bytes_moved_crosszone"],
        "placement_by_zone": ws.stats()["topology"]["placement"]["by_zone"],
    }
    if hasattr(ex, "scale_history"):
        out["resizes"] = len(ex.scale_history)
        out["final_workers"] = ex.current_workers
    ex.shutdown()
    return out


def bench_diurnal_load(rounds_per_period: int = 8, periods: int = 2,
                       n: int = 65536, work_ms: float = 3.0):
    """ISSUE 10 acceptance: under a sinusoidal (diurnal) push load on the
    device fleet, the adaptive runtime -- energy-aware placement plus the
    feedback-driven AdaptiveExecutor -- must beat *every* static
    policy/pool combination (pin / data_gravity x fixed 1 / 8 workers) on
    both total joules (transfer + compute) and p99 push latency.

    The structural story: pin floats the analyzers to the cloud default, so
    every reading crosses the metered device->cloud uplink; data_gravity
    drags them onto the battery-powered device (expensive joules per MB);
    energy-aware placement lands them on the edge rack -- one cheap radio
    hop in, cheap compute, tiny scalars out -- and the adaptive pool tracks
    the wave-width percentiles up the morning ramp and back down at night
    instead of paying peak-pool overhead (or single-lane latency) all day.
    """
    # one diurnal period of wave widths, peak 8 at midday
    period = [1, 2, 4, 8, 8, 4, 2, 1][:rounds_per_period]
    schedule = period * periods
    widths = sorted(set(schedule))
    configs = {
        "adaptive_energy": ("energy", lambda: AdaptiveExecutor(
            inner=ConcurrentExecutor(max_workers=1),
            min_workers=1, max_workers=8)),
        "pin_pool1": ("pin", lambda: ConcurrentExecutor(max_workers=1)),
        "pin_pool8": ("pin", lambda: ConcurrentExecutor(max_workers=8)),
        "gravity_pool1": ("data_gravity",
                          lambda: ConcurrentExecutor(max_workers=1)),
        "gravity_pool8": ("data_gravity",
                          lambda: ConcurrentExecutor(max_workers=8)),
    }
    runs = {}
    for label, (placement, make_ex) in configs.items():
        ws = _diurnal_ws(placement, make_ex(), widths, work_ms)
        runs[label] = _drive_diurnal(ws, schedule, n)
    ada = runs["adaptive_energy"]
    statics = {k: v for k, v in runs.items() if k != "adaptive_energy"}
    return {
        "schedule": schedule,
        "reading_bytes": n * 4,
        "p99_push_s": ada["p99_push_s"],
        "total_energy_j": ada["total_energy_j"],
        "adaptive_resizes": ada["resizes"],
        "energy_margin_x": min(
            s["total_energy_j"] for s in statics.values()
        ) / max(ada["total_energy_j"], 1e-12),
        "latency_margin_x": min(
            s["p99_push_s"] for s in statics.values()
        ) / max(ada["p99_push_s"], 1e-12),
        "adaptive_beats_all_static": all(
            ada["total_energy_j"] < s["total_energy_j"]
            and ada["p99_push_s"] < s["p99_push_s"]
            for s in statics.values()
        ),
        "runs": runs,
    }


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


ALL = {
    "B1_metadata_overhead": bench_metadata_overhead,
    "B2_cache_reuse": bench_cache_reuse,
    "B3_transport_avoidance": bench_transport_avoidance,
    "B4_notification_vs_polling": bench_notification_vs_polling,
    "B5_policy_throughput": bench_policy_throughput,
    "B6_wireframe": bench_wireframe,
    "B7_concurrent_fanout": bench_concurrent_fanout,
    "B8_repeated_push": bench_repeated_push,
    "B10_edge_placement": bench_edge_placement,
    "B11_journal_overhead": bench_journal_overhead,
    "B12_process_pool": bench_process_pool,
    "B13_journal_compaction": bench_journal_compaction,
    "B14_hotpath_throughput": bench_hotpath_throughput,
    "B15_multitenant": bench_multitenant,
    "B16_diurnal_load": bench_diurnal_load,
}
