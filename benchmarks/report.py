"""Assemble the roofline tables (EXPERIMENTS.md §Dry-run / §Roofline) from
the dry-run JSON records under benchmarks/results/dryrun/."""

from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_records(mesh: str, tag: str = "") -> dict:
    out = {}
    for p in sorted(glob.glob(os.path.join(RESULTS, mesh, "*.json"))):
        base = os.path.basename(p)[: -len(".json")]
        parts = base.split("__")
        if tag and (len(parts) < 3 or parts[2] != tag):
            continue
        if not tag and len(parts) > 2:
            continue
        r = json.load(open(p))
        out[(r["arch"], r["shape"])] = r
    return out


def roofline_table_md(mesh: str = "pod16x16", tag: str = "") -> str:
    recs = load_records(mesh, tag)
    archs = sorted({a for a, _ in recs})
    lines = [
        "| arch | shape | kind | compute (ms) | memory (ms) | collective (ms) | bound | MODEL/HLO | roofline frac | what moves the bound |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for a in archs:
        for s in SHAPE_ORDER:
            r = recs.get((a, s))
            if r is None:
                continue
            if "skip" in r:
                lines.append(f"| {a} | {s} | SKIP | — | — | — | — | — | — | {r['skip']} |")
                continue
            hint = _hint(r)
            lines.append(
                "| {arch} | {shape} | {kind} | {c:.1f} | {m:.1f} | {x:.1f} | {b} | {u:.3f} | {rf:.4f} | {hint} |".format(
                    arch=a,
                    shape=s,
                    kind=r["kind"],
                    c=r["t_compute"] * 1e3,
                    m=r["t_memory"] * 1e3,
                    x=r["t_collective"] * 1e3,
                    b=r["bottleneck"],
                    u=r["useful_flops_frac"],
                    rf=r.get("roofline_frac", 0.0),
                    hint=hint,
                )
            )
    return "\n".join(lines)


def _hint(r: dict) -> str:
    b = r["bottleneck"]
    if b == "collective":
        kinds = {
            k: v.get("weighted", 0)
            for k, v in r["collectives"].items()
            if isinstance(v, dict)
        }
        top = max(kinds, key=kinds.get) if kinds else "?"
        return f"cut {top} bytes (bf16 payloads / group-local dispatch / seq-parallel)"
    if b == "memory":
        return "fuse / shrink materialized activations (kernel residency, bf16 stream)"
    return "already compute-bound: raise arithmetic intensity per chip"


def dryrun_summary_md() -> str:
    parts = []
    for mesh in ("pod16x16", "pod2x16x16"):
        recs = load_records(mesh)
        ok = sum(1 for r in recs.values() if "skip" not in r)
        skip = sum(1 for r in recs.values() if "skip" in r)
        mems = [
            r["memory_analysis"]["temp_size_in_bytes"] / 1e9
            for r in recs.values()
            if r.get("memory_analysis")
        ]
        parts.append(
            f"- **{mesh}**: {ok} cells compiled, {skip} recorded skips; "
            f"max per-device temp {max(mems):.2f} GB" if mems else f"- {mesh}: no records"
        )
    return "\n".join(parts)


if __name__ == "__main__":
    print("## Single-pod (16x16) baseline\n")
    print(roofline_table_md("pod16x16"))
    print("\n## Multi-pod (2x16x16)\n")
    print(roofline_table_md("pod2x16x16"))
    print("\n## Summary\n")
    print(dryrun_summary_md())
